"""Distillation-loss kernel (paper §3.1, appendix B.4).

KL(teacher || student) with temperature T over the vocabulary axis,
computed row-blocked: one Pallas grid step reduces a block of rows of the
(R, V) logit matrices to per-row losses. The row dimension R = batch *
seq; V is our char-level vocab and fits one tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_R = 128


def _kd_kernel(s_ref, t_ref, sc_ref, o_ref):
    temp = sc_ref[0]
    s = s_ref[...] / temp
    t = t_ref[...] / temp
    s_lse = jax.scipy.special.logsumexp(s, axis=-1, keepdims=True)
    t_lse = jax.scipy.special.logsumexp(t, axis=-1, keepdims=True)
    log_ps = s - s_lse
    log_pt = t - t_lse
    pt = jnp.exp(log_pt)
    # KL(p_t || p_s) * T^2  (standard distillation scaling)
    o_ref[...] = jnp.sum(pt * (log_pt - log_ps), axis=-1) * temp * temp


@functools.partial(jax.jit, static_argnames=("block_r",))
def kd_loss_rows(student_logits, teacher_logits, temperature, block_r: int = BLOCK_R):
    """Per-row distillation loss; caller masks/averages.

    student_logits, teacher_logits: (R, V). Returns (R,) f32.
    """
    r, v = student_logits.shape
    assert teacher_logits.shape == (r, v)
    rem = (-r) % block_r
    sp = jnp.pad(student_logits.astype(jnp.float32), ((0, rem), (0, 0)))
    tp = jnp.pad(teacher_logits.astype(jnp.float32), ((0, rem), (0, 0)))
    out = pl.pallas_call(
        _kd_kernel,
        grid=(sp.shape[0] // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, v), lambda i: (i, 0)),
            pl.BlockSpec((block_r, v), lambda i: (i, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((sp.shape[0],), jnp.float32),
        interpret=True,
    )(sp, tp, jnp.asarray([temperature], jnp.float32))
    return out[:r]
