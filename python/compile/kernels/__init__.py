# L1: Pallas kernels for the paper's compute hot-spot — the AIMC tile.
#
# Every kernel here is lowered with interpret=True (the CPU PJRT plugin
# cannot execute Mosaic custom-calls); the TPU mapping is documented in
# DESIGN.md §8. Each kernel has a pure-jnp oracle in ref.py, and pytest +
# hypothesis check kernel == oracle across shapes and parameters.
from .analog_mvm import analog_mvm, input_quant, output_quant, apply_weight_noise
from .quant import rtn_weight_quant, clip_weights
from .losses import kd_loss_rows

__all__ = [
    "analog_mvm",
    "input_quant",
    "output_quant",
    "apply_weight_noise",
    "rtn_weight_quant",
    "clip_weights",
    "kd_loss_rows",
]
