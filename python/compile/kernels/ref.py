"""Pure-jnp oracles for every L1 kernel — the correctness ground truth.

These implement the paper's equations (1)-(5) directly with no Pallas,
no tiling, no padding. pytest + hypothesis assert kernel == oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-9


def input_quant_ref(x, beta_in, in_levels):
    """Paper eq. (1)."""
    if in_levels <= 0:
        return jnp.asarray(x, jnp.float32)
    step = beta_in / in_levels
    xq = jnp.clip(x, -beta_in, beta_in)
    return jnp.round(xq / (step + _EPS)) * step


def weight_noise_ref(w, tau, gamma_add, beta_mul):
    """Paper eq. (5); eq. (3) when beta_mul = 0."""
    col_max = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    return w + (gamma_add * col_max + beta_mul * jnp.abs(w)) * tau


def output_quant_ref(y, w, beta_in, lambda_adc, out_levels):
    """Paper eq. (2): round-then-clamp on the global ADC grid."""
    if out_levels <= 0:
        return jnp.asarray(y, jnp.float32)
    col_max = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    beta_adc = lambda_adc * beta_in * col_max
    step = beta_adc / out_levels
    yq = jnp.round(y / (step + _EPS)) * step
    return jnp.clip(yq, -beta_adc, beta_adc)


def analog_mvm_ref(x, w, tau, beta_in, in_levels, gamma_add, beta_mul, lambda_adc, out_levels):
    """Composition of eqs. (1), (5), MVM, (2) — the whole AIMC tile."""
    xq = input_quant_ref(x, beta_in, in_levels)
    wn = weight_noise_ref(w, tau, gamma_add, beta_mul)
    y = xq.astype(jnp.float32) @ wn.astype(jnp.float32)
    return output_quant_ref(y, w, beta_in, lambda_adc, out_levels)


def rtn_weight_quant_ref(w, levels):
    """Per-channel symmetric RTN (paper §4.3)."""
    scale = jnp.max(jnp.abs(w), axis=0, keepdims=True) / levels
    q = jnp.clip(jnp.round(w / jnp.where(scale > 0, scale, 1.0)), -levels, levels)
    return q * scale


def clip_weights_ref(w, alpha):
    """Paper eq. (4) with ddof=0 std."""
    mean = jnp.mean(w, axis=0, keepdims=True)
    std = jnp.sqrt(jnp.mean((w - mean) ** 2, axis=0, keepdims=True))
    return jnp.clip(w, -alpha * std, alpha * std)


def kd_loss_rows_ref(student_logits, teacher_logits, temperature):
    """KL(teacher || student) * T^2 per row."""
    s = student_logits / temperature
    t = teacher_logits / temperature
    log_ps = jax.nn.log_softmax(s, axis=-1)
    log_pt = jax.nn.log_softmax(t, axis=-1)
    pt = jnp.exp(log_pt)
    return jnp.sum(pt * (log_pt - log_ps), axis=-1) * temperature**2


def pcm_sigma_ref(w_norm):
    """Appendix E.3 polynomial: sigma as %% of w_max, w_norm in [0, 1]
    scaled to the paper's conductance axis (x25, see fig. 8)."""
    wx = jnp.abs(w_norm) * 25.0
    sigma_pct = 1.23e-5 * wx**3 - 3.06e-3 * wx**2 + 2.45e-1 * wx + 2.11
    return jnp.where(w_norm == 0.0, 0.0, sigma_pct / 100.0)
