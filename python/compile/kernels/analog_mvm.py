"""Fused AIMC-tile kernel: the paper's analog matrix-vector multiply.

One Pallas grid step simulates what one analog crossbar tile does for a
block of the activation matrix:

    1. static input (DAC) quantization           — paper eq. (1)
    2. weight-noise application                  — paper eq. (3)/(5)
    3. the analog MVM itself                     — fig. 1b
    4. per-column globally-static ADC quantization — paper eq. (2)

All four stages are fused in one kernel so a tile's x-block, w-block and
y-block each cross the HBM<->VMEM boundary exactly once (DESIGN.md §8).

Runtime scalars (so the SAME lowered artifact serves every sweep in the
paper's evaluation — FP16, SI8, O8, gaussian-noise magnitudes):

    beta_in     learnable input range (per layer)      eq. (1)
    in_levels   2^(input bits - 1) - 1; <= 0 bypasses input quantization
    gamma_add   additive noise scale (gamma_weight)    eq. (3)
    beta_mul    multiplicative noise scale             eq. (5)
    lambda_adc  global ADC range multiplier (out_bound)
    out_levels  2^(adc bits - 1) - 1; <= 0 bypasses output quantization

The standard-normal draw tau is an explicit input: the caller (L2 model
or the rust eval harness) owns randomness, keeping the kernel pure.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Block shapes for the tile grid. 128x128 keeps a TPU MXU systolic array
# full; bm=64 bounds the VMEM footprint of the x/y blocks (DESIGN.md §8).
BLOCK_M = 64
BLOCK_N = 128

_EPS = 1e-9


def _round_to_grid(v, levels, bound):
    """Symmetric uniform quantization of v onto `levels` positive steps
    within [-bound, bound]. round-to-nearest (ties-to-even, jnp.round)."""
    step = bound / levels
    return jnp.round(v / (step + _EPS)) * step


def input_quant(x, beta_in, in_levels):
    """Paper eq. (1): clamp to +-beta, then round-to-nearest on the DAC grid.

    in_levels <= 0 bypasses quantization (FP16 input path).
    """
    xq = jnp.clip(x, -beta_in, beta_in)
    xq = _round_to_grid(xq, in_levels, beta_in)
    return jnp.where(in_levels > 0, xq, x)


def apply_weight_noise(w, tau, gamma_add, beta_mul):
    """Paper eq. (5) (eq. (3) is the beta_mul = 0 special case):

        W_noisy[:, i] = W[:, i] + (gamma*max|W[:, i]| + beta*|W[:, i]|) * tau

    Per-channel = per output column. tau ~ N(0, I) is supplied.
    """
    col_max = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    sigma = gamma_add * col_max + beta_mul * jnp.abs(w)
    return w + sigma * tau


def output_quant(y, w, beta_in, lambda_adc, out_levels):
    """Paper eq. (2): per-column ADC quantization with globally static
    range beta_adc_i = lambda_adc * beta_in * max|W[:, i]|.

    Round first, then clamp (the paper's operator order). out_levels <= 0
    bypasses (no ADC modeling).
    """
    col_max = jnp.max(jnp.abs(w), axis=0, keepdims=True)
    beta_adc = lambda_adc * beta_in * col_max
    step = beta_adc / out_levels
    yq = jnp.round(y / (step + _EPS)) * step
    yq = jnp.clip(yq, -beta_adc, beta_adc)
    return jnp.where(out_levels > 0, yq, y)


def _tile_kernel(x_ref, w_ref, tau_ref, s_ref, o_ref):
    """One AIMC tile: full-K column strip of W against a block of x.

    K is kept whole per tile so the per-column max|W| used by both the
    noise model and the ADC range is exact (a physical tile also sees its
    whole column). s_ref holds the 6 runtime scalars.
    """
    beta_in = s_ref[0]
    in_levels = s_ref[1]
    gamma_add = s_ref[2]
    beta_mul = s_ref[3]
    lambda_adc = s_ref[4]
    out_levels = s_ref[5]

    x = x_ref[...]
    w = w_ref[...]
    tau = tau_ref[...]

    # (1) DAC input quantization.
    xq = input_quant(x, beta_in, in_levels)
    # (2) conductance (weight) noise.
    wn = apply_weight_noise(w, tau, gamma_add, beta_mul)
    # (3) the analog MVM (MXU op on TPU).
    y = jnp.dot(xq, wn, preferred_element_type=jnp.float32)
    # (4) ADC output quantization. Ranges use the *programmed target*
    # weights w (hardware calibrates ADC ranges before noise happens).
    o_ref[...] = output_quant(y, w, beta_in, lambda_adc, out_levels)


def _pad_to(v, axis, mult):
    size = v.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return v
    widths = [(0, 0)] * v.ndim
    widths[axis] = (0, rem)
    return jnp.pad(v, widths)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n"))
def analog_mvm(
    x,
    w,
    tau,
    beta_in,
    in_levels,
    gamma_add,
    beta_mul,
    lambda_adc,
    out_levels,
    block_m: int = BLOCK_M,
    block_n: int = BLOCK_N,
):
    """Fused AIMC forward: y = ADC( DAC(x) @ (w + noise) ).

    x: (M, K) activations, w/tau: (K, N). Returns (M, N) f32.
    Shapes are padded to block multiples; K stays whole per tile.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and tau.shape == w.shape
    xp = _pad_to(x.astype(jnp.float32), 0, block_m)
    wp = _pad_to(w.astype(jnp.float32), 1, block_n)
    taup = _pad_to(tau.astype(jnp.float32), 1, block_n)
    scalars = jnp.stack(
        [
            jnp.asarray(beta_in, jnp.float32),
            jnp.asarray(in_levels, jnp.float32),
            jnp.asarray(gamma_add, jnp.float32),
            jnp.asarray(beta_mul, jnp.float32),
            jnp.asarray(lambda_adc, jnp.float32),
            jnp.asarray(out_levels, jnp.float32),
        ]
    )

    grid = (xp.shape[0] // block_m, wp.shape[1] // block_n)
    out = pl.pallas_call(
        _tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((k, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((6,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xp.shape[0], wp.shape[1]), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(xp, wp, taup, scalars)
    return out[:m, :n]
