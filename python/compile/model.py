"""L2: the transformer LM with analog (AIMC-simulated) linear layers.

Decoder-only transformer — RMSNorm, RoPE attention, SwiGLU MLP, tied
embedding head — in which every linear layer is an `AnalogLinear`: the L1
fused AIMC kernel in the forward pass, straight-through estimation in the
backward pass (paper §3.1, Bengio et al. STE). Attention itself is
computed digitally (paper: softmax/attention run in FP16 on digital
units; we use f32 on CPU).

Per-layer learnable input ranges beta follow the paper's schedule:
EMA-initialised from kappa * std(x) for the first `init_steps` steps,
then updated by gradient + decay (appendix D). The forward pass therefore
returns, besides logits, the per-linear std(x) observations the optimizer
needs for the EMA phase.

Everything here is build-time only: `aot.py` lowers these functions to
HLO text artifacts which the rust coordinator executes via PJRT.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from .kernels import analog_mvm, rtn_weight_quant, clip_weights, kd_loss_rows


def _input_quant_traced(x, beta, levels):
    """Traced-safe eq. (1) (ref.input_quant_ref python-branches on levels)."""
    step = beta / levels
    xq = jnp.clip(x, -beta, beta)
    return jnp.round(xq / (step + 1e-9)) * step

# ----------------------------------------------------------------- configs

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
VOCAB = 98  # PAD/BOS/EOS + ASCII 32..126


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int = VOCAB
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 4
    d_ff: int = 176
    seq_len: int = 96
    causal: bool = True
    n_cls: int = 0  # >0: encoder classifier (table 5 experiment)

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


CONFIGS: Dict[str, ModelConfig] = {
    "nano": ModelConfig("nano", d_model=64, n_layers=2, n_heads=4, d_ff=176),
    "micro": ModelConfig("micro", d_model=128, n_layers=4, n_heads=8, d_ff=344),
    "base": ModelConfig("base", d_model=256, n_layers=6, n_heads=8, d_ff=688),
    # Encoder for the analog-RoBERTa experiment (appendix A / table 5):
    # bidirectional attention + 3-way classification head.
    "encnano": ModelConfig(
        "encnano", d_model=64, n_layers=2, n_heads=4, d_ff=176, seq_len=64,
        causal=False, n_cls=3
    ),
}

# Seven analog linears per transformer block: q, k, v, o, gate, up, down.
N_LINEARS = 7

# ------------------------------------------------------------------- params


def init_params(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    """Stacked-layer parameter pytree (all layers share shapes => scan)."""
    d, f, L, v = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    ks = jax.random.split(key, 16)
    s = 0.02

    def nrm(k, *shape, scale=s):
        return (jax.random.normal(k, shape) * scale).astype(jnp.float32)

    params = {
        "emb": nrm(ks[0], v, d),
        "ln_f": jnp.ones((d,), jnp.float32),
        "ln1": jnp.ones((L, d), jnp.float32),
        "ln2": jnp.ones((L, d), jnp.float32),
        "wq": nrm(ks[1], L, d, d),
        "wk": nrm(ks[2], L, d, d),
        "wv": nrm(ks[3], L, d, d),
        "wo": nrm(ks[4], L, d, d),
        "wg": nrm(ks[5], L, d, f),
        "wu": nrm(ks[6], L, d, f),
        "wd": nrm(ks[7], L, f, d),
        # learnable input ranges: one per analog linear (+1 for the head)
        "betas": jnp.full((L, N_LINEARS), 3.0, jnp.float32),
        "beta_head": jnp.full((1,), 3.0, jnp.float32),
    }
    if cfg.n_cls:
        params["cls_w"] = nrm(ks[8], d, cfg.n_cls)
        params["cls_b"] = jnp.zeros((cfg.n_cls,), jnp.float32)
    return params


PARAM_KEYS = [
    "emb",
    "ln_f",
    "ln1",
    "ln2",
    "wq",
    "wk",
    "wv",
    "wo",
    "wg",
    "wu",
    "wd",
    "betas",
    "beta_head",
]
ENC_PARAM_KEYS = PARAM_KEYS + ["cls_w", "cls_b"]

# Weight matrices that live on analog tiles (get clipping / RTN / noise).
ANALOG_WEIGHT_KEYS = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"]
# Embedding is tied to the LM head, which also runs on an analog tile.
TILE_KEYS = ANALOG_WEIGHT_KEYS + ["emb"]


def param_keys(cfg: ModelConfig):
    return ENC_PARAM_KEYS if cfg.n_cls else PARAM_KEYS


# --------------------------------------------------------- hardware scalars

# Runtime scalars describing the simulated hardware. All f32 scalars so
# one artifact serves every paper configuration:
#   in_levels  : 2^(b-1)-1 for SI-b input quantization; <=0 -> FP input
#   dyn_input  : >0 -> per-token dynamic input ranges (DI8, SpinQuant cfg)
#   gamma_add  : additive weight-noise scale (training noise injection)
#   beta_mul   : multiplicative weight-noise scale (eq. 5 ablation)
#   lambda_adc : global ADC range multiplier (out_bound)
#   out_levels : 2^(b-1)-1 for Ob output quantization; <=0 -> no ADC
#   qat_levels : >0 -> W-bit STE weight quantization in fwd (LLM-QAT)
HW_FIELDS = [
    "in_levels",
    "dyn_input",
    "gamma_add",
    "beta_mul",
    "lambda_adc",
    "out_levels",
    "qat_levels",
]


def hw_dict(vals) -> Dict[str, jnp.ndarray]:
    return dict(zip(HW_FIELDS, vals))


def hw_off() -> Dict[str, jnp.ndarray]:
    """Digital FP path: all analog modeling disabled."""
    z = jnp.float32
    return hw_dict(
        [z(-1.0), z(0.0), z(0.0), z(0.0), z(8.0), z(-1.0), z(-1.0)]
    )


# ------------------------------------------------------------ analog linear


@jax.custom_vjp
def _analog_linear_core(x2d, w, tau, beta, hw_vec):
    """y = ADC( DAC(x) @ (Q(w) + noise) ) with STE backward.

    hw_vec = [in_levels, dyn_input, gamma_add, beta_mul, lambda_adc,
              out_levels, qat_levels] (f32 vector, see HW_FIELDS).
    """
    in_levels, dyn_input, gamma_add, beta_mul, lambda_adc, out_levels, qat_levels = hw_vec
    # LLM-QAT baseline: per-channel weight RTN with STE, before noise.
    wq = jnp.where(
        qat_levels > 0,
        _rtn_inline(w, jnp.maximum(qat_levels, 1.0)),
        w,
    )
    # Dynamic per-token input quantization (DI8): quantize outside the
    # kernel with per-row ranges, then bypass the kernel's static DAC.
    row_beta = jnp.max(jnp.abs(x2d), axis=-1, keepdims=True)
    x_dyn = _input_quant_traced(x2d, row_beta, jnp.maximum(in_levels, 1.0))
    use_dyn = jnp.logical_and(dyn_input > 0, in_levels > 0)
    x_eff = jnp.where(use_dyn, x_dyn, x2d)
    kern_in_levels = jnp.where(use_dyn, -1.0, in_levels)
    return analog_mvm(
        x_eff, wq, tau, beta, kern_in_levels, gamma_add, beta_mul, lambda_adc, out_levels
    )


def _rtn_inline(w, levels):
    scale = jnp.max(jnp.abs(w), axis=0, keepdims=True) / levels
    q = jnp.round(w / jnp.where(scale > 0, scale, 1.0))
    return jnp.clip(q, -levels, levels) * scale


def _alc_fwd(x2d, w, tau, beta, hw_vec):
    y = _analog_linear_core(x2d, w, tau, beta, hw_vec)
    return y, (x2d, w, beta, hw_vec)


def _alc_bwd(res, dy):
    """Straight-through estimation (paper §2, §3.1):
    - quantizers (DAC rounding, ADC, weight RTN) are identity in backward;
    - weight noise is ignored (noise-free weights in backward);
    - input clamping routes out-of-range gradient mass to beta, which is
      how the learnable input range receives its 'custom gradient'
      favouring tight ranges (appendix D / AIHWKIT-Lightning).
    """
    x2d, w, beta, hw_vec = res
    in_levels = hw_vec[0]
    dx_full = dy @ w.T
    inside = (jnp.abs(x2d) <= beta) | (in_levels <= 0)
    dx = jnp.where(inside, dx_full, 0.0)
    # d clamp(x, -b, b) / d b = sign(x) outside the range.
    dbeta = jnp.sum(jnp.where(inside, 0.0, dx_full * jnp.sign(x2d)))
    xq = jnp.where(
        in_levels > 0,
        _input_quant_traced(x2d, beta, jnp.maximum(in_levels, 1.0)),
        x2d,
    )
    dw = xq.T @ dy
    return dx, dw, None, dbeta.reshape(()), None


_analog_linear_core.defvjp(_alc_fwd, _alc_bwd)


def analog_linear(x, w, beta, hw, key, gen_tau=True, rot=None):
    """Apply one analog linear to (..., K) activations; returns (..., N)
    plus the std(x) observation used by the input-range EMA schedule.

    gen_tau=False skips the in-graph noise draw (eval artifacts: the rust
    harness injects hardware noise host-side into the weights instead).
    rot: optional fixed orthogonal matrix applied digitally to x before
    the tile (SpinQuant-style rotation; weights must be pre-rotated by
    the matching `spinquant_quant` artifact)."""
    k_in = x.shape[-1]
    x2d = x.reshape(-1, k_in)
    if rot is not None:
        x2d = x2d @ rot
    if gen_tau:
        tau = jax.random.normal(key, w.shape, jnp.float32)
    else:
        tau = jnp.zeros(w.shape, jnp.float32)
    hw_vec = jnp.stack([hw[f] for f in HW_FIELDS])
    y = _analog_linear_core(x2d, w, tau, beta, hw_vec)
    std_obs = jnp.std(x2d)
    return y.reshape(*x.shape[:-1], w.shape[-1]), std_obs


# SpinQuant-style rotations: fixed random orthogonal matrices, one per
# input dimension. Computed IN-GRAPH from a deterministic key (never a
# captured ndarray constant — jax hoists closure constants into extra
# executable parameters, which would break the manifest's input
# contract). Same key => the quantization artifact and the rotated
# forward artifacts agree with no runtime coordination; XLA constant-
# folds the QR at compile time.
# (QR-based jax.random.orthogonal lowers to a typed-FFI lapack custom-
# call that xla_extension 0.5.1 cannot compile, so we build the rotation
# as a product of Householder reflections — pure HLO, still orthogonal
# and outlier-spreading.)
def rotation_matrix(dim: int) -> jnp.ndarray:
    key = jax.random.PRNGKey(1234 + dim)
    r = jnp.eye(dim, dtype=jnp.float32)
    for _ in range(4):
        key, sub = jax.random.split(key)
        v = jax.random.normal(sub, (dim,), jnp.float32)
        v = v / (jnp.sqrt(jnp.sum(v * v)) + 1e-9)
        r = r - 2.0 * jnp.outer(r @ v, v)  # r @ (I - 2 v v^T)
    return r


# ----------------------------------------------------------------- forward


def _rms_norm(x, scale):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + 1e-6) * scale


def _rope(x):
    """Rotary position embedding over the last axis pairs. x: (B,T,H,Dh)."""
    b, t, h, dh = x.shape
    half = dh // 2
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    inv = 10000.0 ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * inv  # (T, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def forward(params, tokens, hw, seed, cfg: ModelConfig, gen_tau=True, rot=False, mlm=False):
    """Full model forward.

    tokens: (B, T) int32. Returns (logits (B,T,V or B,n_cls), std_obs)
    where std_obs = {"betas": (L, 7), "beta_head": (1,)} activation-std
    observations for the input-range EMA schedule.

    Static flags (each combination lowers to its own artifact):
      gen_tau — draw weight-noise normals in-graph (training) vs zeros
                (eval; rust injects hardware noise host-side instead);
      rot     — SpinQuant-style digital input rotations before each tile;
      mlm     — encoder masked-LM head (tied embedding) instead of the
                classification head.
    """
    b, t = tokens.shape
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    key0 = jax.random.PRNGKey(seed)

    x = params["emb"][tokens]  # (B,T,D) digital embedding lookup

    if cfg.causal:
        mask = jnp.tril(jnp.ones((t, t), jnp.float32))
    else:
        mask = jnp.ones((t, t), jnp.float32)
    # padding positions never attend nor get attended to (PAD_ID = 0)
    not_pad = (tokens != PAD_ID).astype(jnp.float32)
    mask = mask[None] * not_pad[:, None, :]
    neg = jnp.float32(-1e9)

    layer_params = {
        k: params[k] for k in ["ln1", "ln2", "wq", "wk", "wv", "wo", "wg", "wu", "wd", "betas"]
    }

    rot_d = rotation_matrix(d) if rot else None
    rot_f = rotation_matrix(cfg.d_ff) if rot else None

    def block(x, lp_key):
        lp, lkey = lp_key
        betas = lp["betas"]  # (7,)
        keys = jax.random.split(lkey, N_LINEARS)

        def lin(xin, w, i, rmat):
            return analog_linear(xin, w, betas[i], hw, keys[i], gen_tau=gen_tau, rot=rmat)

        xn = _rms_norm(x, lp["ln1"])
        q, sq = lin(xn, lp["wq"], 0, rot_d)
        k, sk = lin(xn, lp["wk"], 1, rot_d)
        v, sv = lin(xn, lp["wv"], 2, rot_d)
        q = _rope(q.reshape(b, t, h, dh))
        k = _rope(k.reshape(b, t, h, dh))
        v = v.reshape(b, t, h, dh)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
        att = jnp.where(mask[:, None] > 0, att, neg)
        att = jax.nn.softmax(att, axis=-1)
        ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b, t, d)
        o, so = lin(ctx, lp["wo"], 3, rot_d)
        x = x + o
        xn2 = _rms_norm(x, lp["ln2"])
        g, sg = lin(xn2, lp["wg"], 4, rot_d)
        u, su = lin(xn2, lp["wu"], 5, rot_d)
        mlp_in = jax.nn.silu(g) * u
        dwn, sd = lin(mlp_in, lp["wd"], 6, rot_f)
        x = x + dwn
        stds = jnp.stack([sq, sk, sv, so, sg, su, sd])
        return x, stds

    layer_keys = jax.random.split(jax.random.fold_in(key0, 17), cfg.n_layers)

    def scan_body(x, lp_key):
        x, stds = block(x, lp_key)
        return x, stds

    lp_stacked = ({k: layer_params[k] for k in layer_params}, layer_keys)
    x, std_layers = jax.lax.scan(scan_body, x, lp_stacked)

    x = _rms_norm(x, params["ln_f"])

    if cfg.n_cls and not mlm:
        # mean-pool non-pad positions, digital classifier head
        w_sum = jnp.sum(not_pad, axis=1, keepdims=True) + 1e-6
        pooled = jnp.sum(x * not_pad[..., None], axis=1) / w_sum
        logits = pooled @ params["cls_w"] + params["cls_b"]
        std_obs = {"betas": std_layers, "beta_head": jnp.zeros((1,), jnp.float32)}
        return logits, std_obs

    # Tied-embedding LM head on an analog tile. The head is never rotated:
    # rotating the tied matrix would corrupt the digital embedding lookup
    # (SpinQuant unties them; our lite variant RTN-quantizes the head
    # unrotated instead — see spinquant_all()).
    head_key = jax.random.fold_in(key0, 23)
    logits2d, s_head = analog_linear(
        x.reshape(-1, d),
        params["emb"].T,
        params["beta_head"][0],
        hw,
        head_key,
        gen_tau=gen_tau,
    )
    logits = logits2d.reshape(b, t, cfg.vocab)
    std_obs = {"betas": std_layers, "beta_head": s_head.reshape(1)}
    return logits, std_obs


# ------------------------------------------------------------------ losses


def ce_loss(logits, tokens):
    """Next-token cross entropy, PAD-masked. logits (B,T,V), tokens (B,T)."""
    logp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    tgt = tokens[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    w = (tgt != PAD_ID).astype(jnp.float32)
    return jnp.sum(nll * w) / (jnp.sum(w) + 1e-6)


@jax.custom_vjp
def _kd_rows(s, t, temp):
    return kd_loss_rows(s, t, temp)


def _kd_rows_fwd(s, t, temp):
    return kd_loss_rows(s, t, temp), (s, t, temp)


def _kd_rows_bwd(res, dy):
    # d KL(p_t || p_s)*T^2 / d s = T * (softmax(s/T) - softmax(t/T))
    s, t, temp = res
    ps = jax.nn.softmax(s / temp, axis=-1)
    pt = jax.nn.softmax(t / temp, axis=-1)
    ds = dy[:, None] * temp * (ps - pt)
    return ds, jnp.zeros_like(t), None


_kd_rows.defvjp(_kd_rows_fwd, _kd_rows_bwd)


def kd_loss(student_logits, teacher_logits, tokens, temperature):
    """Distillation loss via the L1 row kernel, PAD-masked.

    The Pallas kernel is wrapped in a custom_vjp (pallas_call has no
    autodiff rule); the backward uses the closed-form KL gradient."""
    b, t, v = student_logits.shape
    rows = _kd_rows(
        student_logits.reshape(-1, v), teacher_logits.reshape(-1, v), temperature
    )
    w = (tokens != PAD_ID).astype(jnp.float32).reshape(-1)
    return jnp.sum(rows * w) / (jnp.sum(w) + 1e-6)


def mlm_ce_loss(logits, targets, mask_w):
    """Masked-LM loss for the encoder pretraining (appendix A)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask_w) / (jnp.sum(mask_w) + 1e-6)


# ------------------------------------------------------- gradient endpoints


def ce_grads(params, tokens, hw, seed, cfg):
    """(loss, grads, std_obs) for CE training — serves teacher pretraining
    (hw off) and the table-10 'no distillation' ablation (hw on)."""

    def f(p):
        logits, std_obs = forward(p, tokens, hw, seed, cfg)
        return ce_loss(logits, tokens), std_obs

    (loss, std_obs), grads = jax.value_and_grad(f, has_aux=True)(params)
    return loss, grads, std_obs


def hwa_kd_grads(params, teacher_params, tokens, hw, seed, temperature, cfg):
    """(loss, grads, std_obs) for distillation HWA training (paper fig. 2b).

    The teacher runs the digital FP path; the student runs the analog
    path described by `hw`. Only student params receive gradients."""
    t_logits, _ = forward(teacher_params, tokens, hw_off(), seed + 1, cfg)
    t_logits = jax.lax.stop_gradient(t_logits)

    def f(p):
        s_logits, std_obs = forward(p, tokens, hw, seed, cfg)
        return kd_loss(s_logits, t_logits, tokens, temperature), std_obs

    (loss, std_obs), grads = jax.value_and_grad(f, has_aux=True)(params)
    return loss, grads, std_obs


def cls_ce_grads(params, tokens, labels, hw, seed, cfg):
    """Encoder classification grads (table 5)."""

    def f(p):
        logits, std_obs = forward(p, tokens, hw, seed, cfg)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        return jnp.mean(nll), std_obs

    (loss, std_obs), grads = jax.value_and_grad(f, has_aux=True)(params)
    return loss, grads, std_obs


def mlm_grads(params, tokens_in, targets, mask_w, hw, seed, cfg):
    """Encoder masked-LM pretraining grads (appendix A)."""

    def f(p):
        logits, std_obs = forward(p, tokens_in, hw, seed, cfg, mlm=True)
        return mlm_ce_loss(logits, targets, mask_w), std_obs

    (loss, std_obs), grads = jax.value_and_grad(f, has_aux=True)(params)
    return loss, grads, std_obs


# ---------------------------------------------------------------- optimizer


def adamw_update(
    params,
    m,
    v,
    grads,
    std_obs,
    step,
    lr,
    alpha_clip,
    kappa,
    init_steps,
    beta_decay,
    cfg,
):
    """AdamW + the paper's HWA post-step transforms:

    1. global grad-norm clip to 1.0 (appendix D);
    2. AdamW (b1=0.9, b2=0.98, eps=1e-6, wd=0.01 on weight matrices);
    3. iterative weight clipping, eq. (4), on analog weight matrices
       (alpha_clip <= 0 disables);
    4. input-range schedule: EMA init from kappa*std(x) while
       step < init_steps, then decay towards tighter ranges.
    """
    b1, b2, eps, wd = 0.9, 0.98, 1e-6, 0.01
    keys = param_keys(cfg)

    # 1. global grad clip
    gnorm = jnp.sqrt(
        sum(jnp.sum(grads[k] ** 2) for k in keys) + 1e-12
    )
    scale = jnp.minimum(1.0, 1.0 / gnorm)

    stepf = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1**stepf
    bc2 = 1.0 - b2**stepf

    new_p, new_m, new_v = {}, {}, {}
    for k in keys:
        g = grads[k] * scale
        if k in ("betas", "beta_head"):
            g = jnp.zeros_like(g)  # handled by the beta schedule below
        nm = b1 * m[k] + (1 - b1) * g
        nv = b2 * v[k] + (1 - b2) * g * g
        upd = (nm / bc1) / (jnp.sqrt(nv / bc2) + eps)
        decay = wd if k in TILE_KEYS else 0.0
        p = params[k] - lr * (upd + decay * params[k])
        new_p[k], new_m[k], new_v[k] = p, nm, nv

    # 3. eq. (4) iterative clipping on the analog weight matrices.
    # Stacked (L, K, N) weights are unrolled over L at trace time
    # (pallas_call has no batching rule for vmap).
    a_clip = jnp.maximum(alpha_clip, 1e-3)

    def clip_stack(wst):
        if wst.ndim == 3:
            return jnp.stack([clip_weights(wst[i], a_clip) for i in range(wst.shape[0])])
        return clip_weights(wst, a_clip)

    for k in ANALOG_WEIGHT_KEYS:
        new_p[k] = jnp.where(alpha_clip > 0, clip_stack(new_p[k]), new_p[k])
    new_p["emb"] = jnp.where(
        alpha_clip > 0, clip_weights(new_p["emb"].T, a_clip).T, new_p["emb"]
    )

    # 4. input-range schedule
    beta_grad_lr = lr * 10.0
    for k in ("betas", "beta_head"):
        if k not in params:
            continue
        ema_target = kappa * std_obs[k]
        ema = 0.98 * params[k] + 0.02 * ema_target
        trained = params[k] * (1.0 - beta_decay) - beta_grad_lr * grads[k] * scale
        nb = jnp.where(stepf <= init_steps, ema, trained)
        new_p[k] = jnp.maximum(nb, 1e-3)

    return new_p, new_m, new_v, gnorm


def _map_stack(fn, wst):
    if wst.ndim == 3:
        return jnp.stack([fn(wst[i]) for i in range(wst.shape[0])])
    return fn(wst)


def rtn_all(params, levels, cfg):
    """Post-training RTN of every analog tile (paper table 3 path)."""
    out = dict(params)
    for k in ANALOG_WEIGHT_KEYS:
        out[k] = _map_stack(lambda w: rtn_weight_quant(w, levels), params[k])
    # tied head: quantize per vocab-channel (columns of emb.T)
    out["emb"] = rtn_weight_quant(params["emb"].T, levels).T
    return out


def spinquant_all(params, levels, cfg):
    """SpinQuant-lite PTQ (paper baseline, §2/§4): rotate each block
    linear's input basis with a fixed orthogonal matrix (outlier
    spreading), then per-channel RTN. Must be paired with the `rot=True`
    forward artifacts, which apply the same rotation to activations.
    The tied head is RTN'd unrotated (see forward())."""
    out = dict(params)
    rot_d = rotation_matrix(cfg.d_model)
    rot_f = rotation_matrix(cfg.d_ff)

    def rot_rtn(rmat):
        return lambda w: rtn_weight_quant(rmat.T @ w, levels)

    for k in ["wq", "wk", "wv", "wo", "wg", "wu"]:
        out[k] = _map_stack(rot_rtn(rot_d), params[k])
    out["wd"] = _map_stack(rot_rtn(rot_f), params["wd"])
    out["emb"] = rtn_weight_quant(params["emb"].T, levels).T
    return out


def zeros_like_params(params):
    return {k: jnp.zeros_like(v) for k, v in params.items()}
