"""AOT export: lower every L2 entry point to HLO *text* artifacts.

Interchange format is HLO text, NOT `.serialize()` — jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1
(what the published `xla` 0.1.6 rust crate links) rejects; the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Besides the `.hlo.txt` files this writes `artifacts/manifest.json`: the
contract with the rust runtime. For every artifact it lists the exact
input order (name, shape, dtype) and output order, plus model dims and
token constants, so the rust side never hard-codes shapes.

Run via `make artifacts` (no-op when inputs are unchanged). Python never
runs again after this: the rust binary executes the artifacts via PJRT.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

# Batch geometry baked into the artifacts (static shapes for XLA).
B_EVAL = 32   # logit-comparison eval batches
B_GEN = 32    # generation/sampling batches
B_TRAIN = 8   # training microbatch (gradient accumulation in rust)

F32 = jnp.float32
I32 = jnp.int32


def spec(shape, dtype=F32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def hw_specs():
    return [(f"hw_{f}", spec(())) for f in M.HW_FIELDS]


def param_specs(cfg, prefix="p"):
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    return [(f"{prefix}_{k}", spec(params[k].shape)) for k in M.param_keys(cfg)]


def unflatten(names, args, prefix):
    """dict of the args whose name starts with `prefix_`, keys stripped."""
    d = {}
    for n, a in zip(names, args):
        if n.startswith(prefix + "_"):
            d[n[len(prefix) + 1 :]] = a
    return d


def hw_from(names, args):
    vals = {n[3:]: a for n, a in zip(names, args) if n.startswith("hw_")}
    return {f: vals[f] for f in M.HW_FIELDS}


def grads_out(cfg, grads):
    return [grads[k] for k in M.param_keys(cfg)]


# --------------------------------------------------------------- registry


def build_registry(cfg_names):
    """[(artifact_name, input_specs, fn)] — fn takes flat args in spec
    order and returns a flat tuple; output names are for the manifest."""
    arts = []

    for cname in cfg_names:
        cfg = M.CONFIGS[cname]
        T = cfg.seq_len
        pspecs = param_specs(cfg)
        keys = M.param_keys(cfg)

        def make(cfg=cfg, pspecs=pspecs, keys=keys, T=T):
            scalar_i = lambda: spec((), I32)

            # ---- eval forwards (no in-graph noise; rust injects host-side)
            def lm_fwd(names, rot):
                ins = pspecs + [("tokens", spec((B_EVAL, T), I32))] + hw_specs() + [("seed", scalar_i())]

                def f(*args):
                    ns = [n for n, _ in ins]
                    p = unflatten(ns, args, "p")
                    hw = hw_from(ns, args)
                    tokens = args[len(pspecs)]
                    seed = args[-1]
                    logits, stds = M.forward(p, tokens, hw, seed, cfg, gen_tau=False, rot=rot)
                    return (logits, stds["betas"], stds["beta_head"])

                return ins, f, ["logits", "std_betas", "std_beta_head"]

            def lm_loss():
                ins = pspecs + [("tokens", spec((B_EVAL, T), I32))] + hw_specs() + [("seed", scalar_i())]

                def f(*args):
                    ns = [n for n, _ in ins]
                    p = unflatten(ns, args, "p")
                    hw = hw_from(ns, args)
                    tokens = args[len(pspecs)]
                    logits, _ = M.forward(p, tokens, hw, args[-1], cfg, gen_tau=False)
                    return (M.ce_loss(logits, tokens),)

                return ins, f, ["loss"]

            def lm_sample(rot):
                ins = (
                    pspecs
                    + [("tokens", spec((B_GEN, T), I32)), ("lens", spec((B_GEN,), I32))]
                    + hw_specs()
                    + [("seed", scalar_i())]
                )

                def f(*args):
                    ns = [n for n, _ in ins]
                    p = unflatten(ns, args, "p")
                    hw = hw_from(ns, args)
                    tokens, lens = args[len(pspecs)], args[len(pspecs) + 1]
                    logits, _ = M.forward(p, tokens, hw, args[-1], cfg, gen_tau=False, rot=rot)
                    idx = jnp.clip(lens - 1, 0, T - 1)
                    last = jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0]
                    return (last,)

                return ins, f, ["last_logits"]

            # ---- training grads
            def ce_grads():
                ins = pspecs + [("tokens", spec((B_TRAIN, T), I32))] + hw_specs() + [("seed", scalar_i())]

                def f(*args):
                    ns = [n for n, _ in ins]
                    p = unflatten(ns, args, "p")
                    hw = hw_from(ns, args)
                    tokens = args[len(pspecs)]
                    loss, grads, stds = M.ce_grads(p, tokens, hw, args[-1], cfg)
                    return (loss, *grads_out(cfg, grads), stds["betas"], stds["beta_head"])

                return ins, f, ["loss"] + [f"g_{k}" for k in keys] + ["std_betas", "std_beta_head"]

            def hwa_grads():
                tspecs = param_specs(cfg, prefix="t")
                ins = (
                    pspecs
                    + tspecs
                    + [("tokens", spec((B_TRAIN, T), I32))]
                    + hw_specs()
                    + [("seed", scalar_i()), ("temperature", spec(()))]
                )

                def f(*args):
                    ns = [n for n, _ in ins]
                    p = unflatten(ns, args, "p")
                    tp = unflatten(ns, args, "t")
                    hw = hw_from(ns, args)
                    tokens = args[len(pspecs) + len(tspecs)]
                    seed, temp = args[-2], args[-1]
                    loss, grads, stds = M.hwa_kd_grads(p, tp, tokens, hw, seed, temp, cfg)
                    return (loss, *grads_out(cfg, grads), stds["betas"], stds["beta_head"])

                return ins, f, ["loss"] + [f"g_{k}" for k in keys] + ["std_betas", "std_beta_head"]

            # ---- optimizer
            def adamw():
                gspecs = [(f"g_{k}", s) for (f_, s), k in zip(pspecs, keys) for f_ in [f_]]
                mspecs = [(f"m_{k}", s) for (_, s), k in zip(pspecs, keys)]
                vspecs = [(f"v_{k}", s) for (_, s), k in zip(pspecs, keys)]
                betas_shape = M.init_params(jax.random.PRNGKey(0), cfg)["betas"].shape
                ins = (
                    pspecs
                    + mspecs
                    + vspecs
                    + gspecs
                    + [
                        ("std_betas", spec(betas_shape)),
                        ("std_beta_head", spec((1,))),
                        ("step", spec((), I32)),
                        ("lr", spec(())),
                        ("alpha_clip", spec(())),
                        ("kappa", spec(())),
                        ("init_steps", spec(())),
                        ("beta_decay", spec(())),
                    ]
                )

                def f(*args):
                    ns = [n for n, _ in ins]
                    p = unflatten(ns, args, "p")
                    m = unflatten(ns, args, "m")
                    v = unflatten(ns, args, "v")
                    g = unflatten(ns, args, "g")
                    base = 4 * len(pspecs)
                    std_obs = {"betas": args[base], "beta_head": args[base + 1]}
                    step, lr, alpha, kappa, init_steps, beta_decay = args[base + 2 : base + 8]
                    np_, nm, nv, gnorm = M.adamw_update(
                        p, m, v, g, std_obs, step, lr, alpha, kappa, init_steps, beta_decay, cfg
                    )
                    return (
                        *[np_[k] for k in keys],
                        *[nm[k] for k in keys],
                        *[nv[k] for k in keys],
                        gnorm,
                    )

                outs = (
                    [f"p_{k}" for k in keys]
                    + [f"m_{k}" for k in keys]
                    + [f"v_{k}" for k in keys]
                    + ["gnorm"]
                )
                return ins, f, outs

            # ---- PTQ
            def quant(method):
                ins = pspecs + [("levels", spec(()))]

                def f(*args):
                    ns = [n for n, _ in ins]
                    p = unflatten(ns, args, "p")
                    q = (M.rtn_all if method == "rtn" else M.spinquant_all)(p, args[-1], cfg)
                    return tuple(q[k] for k in keys)

                return ins, f, [f"p_{k}" for k in keys]

            return lm_fwd, lm_loss, lm_sample, ce_grads, hwa_grads, adamw, quant

        lm_fwd, lm_loss, lm_sample, ce_grads, hwa_grads, adamw, quant = make()

        if cfg.n_cls:
            # encoder endpoints (table 5) are registered separately below
            arts.extend(_encoder_artifacts(cname, cfg))
            continue

        arts.append((f"{cname}_lm_fwd", *lm_fwd("", rot=False)))
        arts.append((f"{cname}_lm_fwd_rot", *lm_fwd("", rot=True)))
        arts.append((f"{cname}_lm_loss", *lm_loss()))
        arts.append((f"{cname}_lm_sample", *lm_sample(rot=False)))
        arts.append((f"{cname}_lm_sample_rot", *lm_sample(rot=True)))
        arts.append((f"{cname}_ce_grads", *ce_grads()))
        arts.append((f"{cname}_hwa_grads", *hwa_grads()))
        arts.append((f"{cname}_adamw_update", *adamw()))
        arts.append((f"{cname}_rtn_quant", *quant("rtn")))
        arts.append((f"{cname}_spinquant_quant", *quant("spinquant")))
    return arts


def _encoder_artifacts(cname, cfg):
    """Encoder endpoints for the analog-RoBERTa experiment (appendix A)."""
    T = cfg.seq_len
    B = B_TRAIN
    pspecs = param_specs(cfg)
    keys = M.param_keys(cfg)
    arts = []

    def cls_fwd():
        ins = pspecs + [("tokens", spec((B_EVAL, T), I32))] + hw_specs() + [("seed", spec((), I32))]

        def f(*args):
            ns = [n for n, _ in ins]
            p = unflatten(ns, args, "p")
            hw = hw_from(ns, args)
            logits, _ = M.forward(p, args[len(pspecs)], hw, args[-1], cfg, gen_tau=False)
            return (logits,)

        return ins, f, ["logits"]

    def cls_grads():
        ins = (
            pspecs
            + [("tokens", spec((B, T), I32)), ("labels", spec((B,), I32))]
            + hw_specs()
            + [("seed", spec((), I32))]
        )

        def f(*args):
            ns = [n for n, _ in ins]
            p = unflatten(ns, args, "p")
            hw = hw_from(ns, args)
            loss, grads, stds = M.cls_ce_grads(
                p, args[len(pspecs)], args[len(pspecs) + 1], hw, args[-1], cfg
            )
            return (loss, *[grads[k] for k in keys], stds["betas"], stds["beta_head"])

        return ins, f, ["loss"] + [f"g_{k}" for k in keys] + ["std_betas", "std_beta_head"]

    def mlm_grads():
        ins = (
            pspecs
            + [
                ("tokens_in", spec((B, T), I32)),
                ("targets", spec((B, T), I32)),
                ("mask_w", spec((B, T))),
            ]
            + hw_specs()
            + [("seed", spec((), I32))]
        )

        def f(*args):
            ns = [n for n, _ in ins]
            p = unflatten(ns, args, "p")
            hw = hw_from(ns, args)
            i0 = len(pspecs)
            loss, grads, stds = M.mlm_grads(
                p, args[i0], args[i0 + 1], args[i0 + 2], hw, args[-1], cfg
            )
            return (loss, *[grads[k] for k in keys], stds["betas"], stds["beta_head"])

        return ins, f, ["loss"] + [f"g_{k}" for k in keys] + ["std_betas", "std_beta_head"]

    def adamw():
        mspecs = [(f"m_{k}", s) for (_, s), k in zip(pspecs, keys)]
        vspecs = [(f"v_{k}", s) for (_, s), k in zip(pspecs, keys)]
        gspecs = [(f"g_{k}", s) for (_, s), k in zip(pspecs, keys)]
        betas_shape = M.init_params(jax.random.PRNGKey(0), cfg)["betas"].shape
        ins = (
            pspecs
            + mspecs
            + vspecs
            + gspecs
            + [
                ("std_betas", spec(betas_shape)),
                ("std_beta_head", spec((1,))),
                ("step", spec((), I32)),
                ("lr", spec(())),
                ("alpha_clip", spec(())),
                ("kappa", spec(())),
                ("init_steps", spec(())),
                ("beta_decay", spec(())),
            ]
        )

        def f(*args):
            ns = [n for n, _ in ins]
            p = unflatten(ns, args, "p")
            m = unflatten(ns, args, "m")
            v = unflatten(ns, args, "v")
            g = unflatten(ns, args, "g")
            base = 4 * len(pspecs)
            std_obs = {"betas": args[base], "beta_head": args[base + 1]}
            step, lr, alpha, kappa, init_steps, beta_decay = args[base + 2 : base + 8]
            np_, nm, nv, gnorm = M.adamw_update(
                p, m, v, g, std_obs, step, lr, alpha, kappa, init_steps, beta_decay, cfg
            )
            return (
                *[np_[k] for k in keys],
                *[nm[k] for k in keys],
                *[nv[k] for k in keys],
                gnorm,
            )

        outs = (
            [f"p_{k}" for k in keys]
            + [f"m_{k}" for k in keys]
            + [f"v_{k}" for k in keys]
            + ["gnorm"]
        )
        return ins, f, outs

    arts.append((f"{cname}_cls_fwd", *cls_fwd()))
    arts.append((f"{cname}_cls_grads", *cls_grads()))
    arts.append((f"{cname}_mlm_grads", *mlm_grads()))
    arts.append((f"{cname}_adamw_update", *adamw()))
    return arts


# --------------------------------------------------------------- lowering


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--configs", default="nano,micro,base,encnano")
    ap.add_argument("--only", default="", help="substring filter on artifact names")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cfg_names = [c for c in args.configs.split(",") if c]
    registry = build_registry(cfg_names)

    manifest = {
        "vocab": M.VOCAB,
        "pad_id": M.PAD_ID,
        "bos_id": M.BOS_ID,
        "eos_id": M.EOS_ID,
        "hw_fields": M.HW_FIELDS,
        "batch": {"eval": B_EVAL, "gen": B_GEN, "train": B_TRAIN},
        "configs": {},
        "artifacts": {},
    }
    for cname in cfg_names:
        cfg = M.CONFIGS[cname]
        params = M.init_params(jax.random.PRNGKey(0), cfg)
        manifest["configs"][cname] = {
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff,
            "seq_len": cfg.seq_len,
            "vocab": cfg.vocab,
            "n_cls": cfg.n_cls,
            "param_keys": M.param_keys(cfg),
            "param_shapes": {k: list(params[k].shape) for k in M.param_keys(cfg)},
            "n_params": int(sum(params[k].size for k in M.param_keys(cfg))),
        }

    for name, ins, fn, out_names in registry:
        if args.only and args.only not in name:
            continue
        t0 = time.time()
        specs = [s for _, s in ins]
        # keep_unused: the manifest promises EVERY input, even ones a
        # particular configuration ignores (e.g. `seed` in no-noise eval
        # forwards) — jit would otherwise drop them from the executable
        # signature and break the rust-side argument contract.
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"name": n, "shape": list(s.shape), "dtype": "i32" if s.dtype == I32 else "f32"}
                for n, s in ins
            ],
            "outputs": out_names,
        }
        print(f"  lowered {name}: {len(text)/1e6:.2f} MB in {time.time()-t0:.1f}s", file=sys.stderr)

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['artifacts'])} artifacts to {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
